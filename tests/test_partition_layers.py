"""Layer bucketing regressions: untagged interior nodes must attach to the
*topologically previous* tag (last seen in node-id order), not the
numerically largest tag seen so far."""
from repro.core.ir import Graph
from repro.core.partition import partition_layers, split_layer_buckets


def _chain(tags):
    """A chain graph whose nodes carry the given layer tags (None allowed)."""
    g = Graph()
    prev = g.add("input", (), (4,), "float32")
    ids = []
    for t in tags:
        prev = g.add("tanh", [prev], (4,), "float32", layer=t)
        ids.append(prev)
    g.mark_output(prev)
    return g, ids


def test_untagged_interior_attaches_to_last_seen_tag():
    # tags interleave non-monotonically: 5, 3, <untagged>, 7 — the untagged
    # node belongs to layer 3 (topologically previous), not 5 (numeric max)
    g, ids = _chain([5, 3, None, 7])
    buckets = split_layer_buckets(g)
    assert ids[2] in buckets[3]
    assert ids[2] not in buckets[5]


def test_untagged_interior_monotone_tags():
    g, ids = _chain([0, None, 1, None, 2])
    buckets = split_layer_buckets(g)
    assert ids[1] in buckets[0]
    assert ids[3] in buckets[1]


def test_pre_and_post_buckets():
    g = Graph()
    a = g.add("input", (), (4,), "float32")
    pre = g.add("neg", [a], (4,), "float32")
    l0 = g.add("tanh", [pre], (4,), "float32", layer=0)
    post = g.add("neg", [l0], (4,), "float32")
    g.mark_output(post)
    buckets = split_layer_buckets(g)
    assert pre in buckets["pre"] and a in buckets["pre"]
    assert post in buckets["post"]
    plans = partition_layers(g, g)
    assert [p.key for p in plans] == ["pre", 0, "post"]
