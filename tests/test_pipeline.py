"""Pipeline parallelism over the pod axis: GPipe schedule == sequential."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

_SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_pipeline_matches_sequential():
    _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.parallel.pipeline import pipeline_forward

        N_STAGES, N_MICRO, MB, D = 4, 6, 2, 8
        mesh = Mesh(np.array(jax.devices()[:N_STAGES]).reshape(N_STAGES), ("pod",))
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (N_STAGES, D, D), jnp.float32) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (N_MICRO, MB, D), jnp.float32)

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        ref = x
        for s in range(N_STAGES):
            ref = jax.vmap(lambda xx: stage_fn(Ws[s], xx))(ref)

        from repro.compat import shard_map
        fn = shard_map(
            lambda w, xx: pipeline_forward(lambda p, h: stage_fn(p[0], h), w, xx,
                                           n_stages=N_STAGES),
            mesh=mesh, in_specs=(P("pod"), P()), out_specs=P(), check_vma=False)
        with mesh:
            out = jax.jit(fn)(Ws, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """))


def test_pipeline_grad_matches_sequential():
    """jax.grad through the ppermute pipeline equals the sequential grad."""
    _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.parallel.pipeline import pipeline_forward

        N_STAGES, N_MICRO, MB, D = 2, 4, 2, 6
        mesh = Mesh(np.array(jax.devices()[:N_STAGES]).reshape(N_STAGES), ("pod",))
        Ws = jax.random.normal(jax.random.PRNGKey(0), (N_STAGES, D, D), jnp.float32) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (N_MICRO, MB, D), jnp.float32)

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        def seq_loss(w):
            h = x
            for s in range(N_STAGES):
                h = jax.vmap(lambda xx: stage_fn(w[s], xx))(h)
            return jnp.sum(h * h)

        def pipe_loss(w, xx):
            out = pipeline_forward(lambda p, h: stage_fn(p[0], h), w, xx,
                                   n_stages=N_STAGES)
            # replicated output => the per-rank loss is counted n_stages
            # times under shard_map grad; normalize (see pipeline.py note)
            return jnp.sum(out * out) / N_STAGES

        gref = jax.grad(seq_loss)(Ws)
        from repro.compat import shard_map
        fn = shard_map(jax.grad(pipe_loss), mesh=mesh,
                           in_specs=(P("pod"), P()), out_specs=P("pod"),
                           check_vma=False)
        with mesh:
            gpipe = jax.jit(fn)(Ws, x)
        np.testing.assert_allclose(np.asarray(gpipe), np.asarray(gref),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """))
