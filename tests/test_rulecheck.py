"""The rule-registry static checker: the shipped registry must be clean
(dead rules / orphan kinds / drift gate CI), and each defect class must
actually trip on a synthetic registry built to exhibit it."""
import json

from repro.analysis import check_registry, trace_ops
from repro.core.relations import DUP, LOOPRED, SHARD, SLICEGRP
from repro.core.rules.registry import DEFAULT_REGISTRY, RuleRegistry

ARCH = "gemma_2b"


def _registry(*rules):
    """RuleRegistry from (name, ops, consumes, produces) tuples."""
    reg = RuleRegistry()
    for name, ops, consumes, produces in rules:
        reg.rule(name, ops, consumes=consumes, produces=produces)(
            lambda prop, node: None)
    return reg


# ------------------------------------------------------------ the real one

def test_shipped_registry_is_clean():
    rep = check_registry()
    assert rep.ok, rep.summary()
    assert not rep.dead_rules and not rep.orphan_kinds and not rep.drift
    assert rep.num_rules == len(DEFAULT_REGISTRY.rules)
    # every kind is produced by someone (or seeded) and consumed by someone
    assert rep.producers[SHARD] and rep.consumers[SHARD]


def test_shipped_registry_covers_zoo_ops():
    ops = trace_ops([ARCH], tp=4)
    rep = check_registry(traced_ops=ops)
    assert rep.ok, rep.summary()
    assert rep.num_ops > 0
    # uncovered ops are informational, never gate
    assert isinstance(rep.uncovered_ops, list)


def test_report_json_shape():
    d = json.loads(check_registry().to_json())
    assert d["schema"] == 1 and d["ok"] is True
    for key in ("dead_rules", "orphan_kinds", "drift", "producers",
                "consumers", "uncovered_ops"):
        assert key in d


# ------------------------------------------------------- synthetic defects

def test_dead_rule_detected(tmp_path):
    # consumes loopred, which this registry neither produces nor seeds
    reg = _registry(
        ("alive", ["dot"], [SHARD], [SHARD]),
        ("dead", ["dot"], [LOOPRED], [SHARD]),
    )
    rep = check_registry(reg, rules_dir=tmp_path)
    assert not rep.ok
    assert [r["rule"] for r in rep.dead_rules] == ["dead"]


def test_empty_consumes_is_alive(tmp_path):
    # fire-on-any-change rules (congruence) must never read as dead
    reg = _registry(("congruence", ["dot"], [], [DUP]))
    rep = check_registry(reg, rules_dir=tmp_path)
    assert not rep.dead_rules


def test_orphan_kind_detected(tmp_path):
    # slicegrp is produced but consumed by no rule, and it is not an
    # output-check kind — deriving it is wasted work
    reg = _registry(
        ("producer", ["slice"], [SHARD], [SLICEGRP]),
        ("user", ["dot"], [SHARD], [SHARD]),
    )
    rep = check_registry(reg, rules_dir=tmp_path)
    assert SLICEGRP in rep.orphan_kinds and not rep.ok


def test_seeded_kinds_not_orphans_when_output_checked(tmp_path):
    # dup/shard are seeded + output-checked: a registry that only consumes
    # them stays clean
    reg = _registry(("elem", ["add"], [DUP, SHARD], [DUP, SHARD]))
    rep = check_registry(reg, rules_dir=tmp_path)
    assert rep.ok, rep.summary()


def test_unproduced_consumed_detected(tmp_path):
    # slicegrp consumed but neither produced nor seeded
    reg = _registry(("reader", ["concat"], [SLICEGRP, SHARD], [SHARD]))
    rep = check_registry(reg, rules_dir=tmp_path)
    assert SLICEGRP in rep.unproduced_consumed and not rep.ok


def test_drift_detected_from_module_source(tmp_path):
    # a family module whose source builds Fact(SLICEGRP, ...) and reads
    # LOOPRED, while its registered rule declares neither
    (tmp_path / "sliceops.py").write_text(
        "def rule_slice(prop, node):\n"
        "    prop.emit(Fact(SLICEGRP, 0, 0, 2, lay))\n"
        "    for f in prop.store.facts_kind(0, LOOPRED):\n"
        "        pass\n")
    reg = RuleRegistry()

    def rule_slice(prop, node):
        return None

    rule_slice.__module__ = "tests.synthetic.sliceops"
    reg.rule("slice_rule", ["slice"], consumes=[SHARD],
             produces=[SHARD])(rule_slice)
    rep = check_registry(reg, rules_dir=tmp_path)
    directions = {(d["kind"], d["direction"]) for d in rep.drift}
    assert (SLICEGRP, "produces") in directions, rep.summary()
    assert (LOOPRED, "consumes") in directions, rep.summary()
    assert not rep.ok


def test_declared_usage_is_not_drift(tmp_path):
    # same source, but the rule declares what the source does: clean
    (tmp_path / "sliceops.py").write_text(
        "def rule_slice(prop, node):\n"
        "    prop.emit(Fact(SLICEGRP, 0, 0, 2, lay))\n")
    reg = RuleRegistry()

    def rule_slice(prop, node):
        return None

    rule_slice.__module__ = "tests.synthetic.sliceops"
    reg.rule("slice_rule", ["slice"], consumes=[SHARD],
             produces=[SHARD, SLICEGRP])(rule_slice)
    rep = check_registry(reg, rules_dir=tmp_path)
    assert not rep.drift, rep.summary()


# ------------------------------------------------------------ CLI verb

def test_cli_rulecheck_exit0(tmp_path, capsys):
    from repro.verify.cli import main as cli_main

    out = tmp_path / "rc.json"
    assert cli_main(["rulecheck", "--json", str(out)]) == 0
    d = json.loads(out.read_text())
    assert d["ok"] and d["num_rules"] == len(DEFAULT_REGISTRY.rules)


def test_cli_rulecheck_usage_error():
    from repro.verify.cli import main as cli_main

    assert cli_main(["rulecheck", "--ops-from", "nope"]) == 2
