"""Soundness of the relational rules against a numpy SPMD simulator.

Every fact kind has an executable meaning (relations.py docstring).  We build
small random baseline/distributed graph pairs, run the Propagator, then
*execute both graphs* — the distributed one on c simulated devices — and
assert every derived fact holds numerically.  A fact the simulator falsifies
would be an unsound rule; none may exist (paper §5.1 soundness argument).
"""
import numpy as np
import pytest

try:  # property tests need hypothesis; the plain tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core.ir import Graph
from repro.core.relations import DUP, PARTIAL, SHARD
from repro.core.rules import Propagator

C = 4  # simulated device count


# --------------------------------------------------------------------------
# tiny SPMD simulator: evaluate a dist graph per device


def eval_graph(g: Graph, leaf_vals: dict, rank=None, axis_size=C):
    """Evaluate; ``rank`` not None -> per-device program with collectives
    evaluated against `all_vals` gathered lazily (two-pass)."""
    vals: dict[int, np.ndarray] = {}
    for n in g:
        if n.id in leaf_vals:
            vals[n.id] = leaf_vals[n.id]
            continue
        ins = [vals[i] for i in n.inputs]
        if n.op == "dot":
            (lc, rc), (lb, rb) = n.param("dimension_numbers")
            vals[n.id] = np.einsum("ij,jk->ik", ins[0], ins[1]) if (lc, rc) == ((1,), (0,)) \
                else np.tensordot(ins[0], ins[1], axes=(lc, rc))
        elif n.op == "add":
            vals[n.id] = ins[0] + ins[1]
        elif n.op == "mul":
            vals[n.id] = ins[0] * ins[1]
        elif n.op == "tanh":
            vals[n.id] = np.tanh(ins[0])
        elif n.op == "neg":
            vals[n.id] = -ins[0]
        elif n.op == "exp":
            vals[n.id] = np.exp(ins[0])
        elif n.op == "reshape":
            vals[n.id] = ins[0].reshape(n.shape)
        elif n.op == "transpose":
            vals[n.id] = ins[0].transpose(n.param("permutation"))
        elif n.op == "reduce_sum":
            vals[n.id] = ins[0].sum(axis=tuple(n.param("axes")))
        elif n.op == "reduce_max":
            vals[n.id] = ins[0].max(axis=tuple(n.param("axes")))
        elif n.op == "slice":
            sl = tuple(slice(s, lim) for s, lim in zip(n.param("start_indices"),
                                                     n.param("limit_indices")))
            vals[n.id] = ins[0][sl]
        elif n.op == "dynamic_slice":
            starts = [int(s) for s in ins[1:]]
            sl = tuple(slice(st, st + sz) for st, sz in zip(starts, n.shape))
            vals[n.id] = ins[0][sl]
        elif n.op == "const":
            vals[n.id] = np.asarray(n.param("value"))
        elif n.op == "axis_index":
            vals[n.id] = np.int64(rank or 0)
        elif n.op == "gather":
            # embedding-style gather: indices (..., 1) into operand rows
            vals[n.id] = np.take(ins[0], ins[1][..., 0].astype(int), axis=0)
        elif n.op == "scatter_add":
            # row scatter-add: operand (V, D), indices (..., 1), updates (..., D)
            out = ins[0].copy()
            np.add.at(out, ins[1][..., 0].reshape(-1).astype(int),
                      ins[2].reshape(-1, ins[2].shape[-1]))
            vals[n.id] = out
        else:
            raise NotImplementedError(n.op)
    return vals


def eval_spmd(g: Graph, leaf_vals_per_rank: list):
    """Evaluate the per-device graph on all ranks with real collectives."""
    vals = [dict() for _ in range(C)]

    def get(r, i):
        return vals[r][i]

    for n in g:
        if all(n.id in leaf_vals_per_rank[r] for r in range(C)) and not n.inputs:
            for r in range(C):
                vals[r][n.id] = leaf_vals_per_rank[r][n.id]
            continue
        if n.op == "all_reduce":
            op = n.param("reduce_op", "add")
            stack = np.stack([get(r, n.inputs[0]) for r in range(C)])
            red = stack.sum(0) if op == "add" else stack.max(0)
            for r in range(C):
                vals[r][n.id] = red
            continue
        if n.op == "all_gather":
            dim = n.param("all_gather_dimension", 0)
            parts = [get(r, n.inputs[0]) for r in range(C)]
            if n.param("tiled", False):
                gathered = np.concatenate(parts, axis=dim)
            else:
                gathered = np.stack(parts, axis=dim)
            for r in range(C):
                vals[r][n.id] = gathered
            continue
        if n.op == "reduce_scatter":
            dim = n.param("scatter_dimension", 0)
            total = np.stack([get(r, n.inputs[0]) for r in range(C)]).sum(0)
            chunks = np.split(total, C, axis=dim)
            for r in range(C):
                vals[r][n.id] = chunks[r]
            continue
        if n.op == "all_to_all":
            sa, ca = n.param("split_axis"), n.param("concat_axis")
            for r in range(C):
                pieces = []
                for j in range(C):
                    chunk = np.split(get(j, n.inputs[0]), C, axis=sa)[r]
                    pieces.append(chunk)
                vals[r][n.id] = np.concatenate(pieces, axis=ca)
            continue
        if n.op == "axis_index":
            for r in range(C):
                vals[r][n.id] = np.int64(r)
            continue
        for r in range(C):
            ins = [vals[r][i] for i in n.inputs]
            vals[r][n.id] = _eval_one(n, ins)
    return vals


def _eval_one(n, ins):
    g = Graph()
    fake_ids = []
    for x in ins:
        fake_ids.append(g.add("input", (), x.shape, str(x.dtype)))
    nid = g.add(n.op, fake_ids, n.shape, n.dtype, {k: v for k, v in n.params})
    leaf = dict(zip(fake_ids, ins))
    return eval_graph(g, leaf)[nid]


def check_facts(prop, gb, gd, base_vals, dist_vals_per_rank):
    """Assert every derived fact holds under the simulator."""
    checked = 0
    bv = eval_graph(gb, base_vals)
    dv = eval_spmd(gd, dist_vals_per_rank)
    for d_id, facts in prop.store.by_dist.items():
        for f in facts:
            B = bv[f.base]
            Ds = [dv[r][d_id] for r in range(C)]
            if f.kind == DUP:
                exp = f.layout.apply(B)
                for D in Ds:
                    np.testing.assert_allclose(D, exp, rtol=1e-5, atol=1e-6,
                                               err_msg=f.short())
            elif f.kind == SHARD:
                stacked = np.stack(Ds)
                np.testing.assert_allclose(
                    stacked.reshape(f.layout.dst_shape), f.layout.apply(B),
                    rtol=1e-5, atol=1e-6, err_msg=f.short())
            elif f.kind == PARTIAL:
                red = np.stack(Ds).sum(0) if f.reduce_op == "add" else np.stack(Ds).max(0)
                np.testing.assert_allclose(red, f.layout.apply(B), rtol=1e-5,
                                           atol=1e-5, err_msg=f.short())
            else:
                continue
            checked += 1
    return checked


# --------------------------------------------------------------------------


def _mlp_pair(reduce_kind="all_reduce"):
    """Megatron MLP pair + input values."""
    rng = np.random.default_rng(0)
    B, H, F = 4, 8, 16
    dn = (((1,), (0,)), ((), ()))
    gb = Graph("base")
    x = gb.add("input", (), (B, H), "float64")
    w1 = gb.add("param", (), (H, F), "float64")
    w2 = gb.add("param", (), (F, H), "float64")
    h = gb.add("dot", [x, w1], (B, F), "float64", {"dimension_numbers": dn})
    t = gb.add("tanh", [h], (B, F), "float64")
    o = gb.add("dot", [t, w2], (B, H), "float64", {"dimension_numbers": dn})
    res = gb.add("add", [o, x], (B, H), "float64")
    gb.mark_output(res)

    gd = Graph("dist")
    xd = gd.add("input", (), (B, H), "float64")
    w1d = gd.add("param", (), (H, F // C), "float64")
    w2d = gd.add("param", (), (F // C, H), "float64")
    hd = gd.add("dot", [xd, w1d], (B, F // C), "float64", {"dimension_numbers": dn})
    td = gd.add("tanh", [hd], (B, F // C), "float64")
    od = gd.add("dot", [td, w2d], (B, H), "float64", {"dimension_numbers": dn})
    if reduce_kind == "all_reduce":
        rd = gd.add("all_reduce", [od], (B, H), "float64",
                    {"reduce_op": "add", "axes": ("model",)})
    else:
        rd = gd.add("reduce_scatter", [od], (B, H // C), "float64",
                    {"scatter_dimension": 1, "reduce_op": "add", "axes": ("model",),
                     "tiled": True})
        rd = gd.add("all_gather", [rd], (B, H), "float64",
                    {"all_gather_dimension": 1, "tiled": True, "axes": ("model",)})
    resd = gd.add("add", [rd, xd], (B, H), "float64")
    gd.mark_output(resd)

    X = rng.standard_normal((B, H))
    W1 = rng.standard_normal((H, F))
    W2 = rng.standard_normal((F, H))
    base_vals = {x: X, w1: W1, w2: W2}
    dist_vals = [
        {xd: X, w1d: np.split(W1, C, 1)[r], w2d: np.split(W2, C, 0)[r]}
        for r in range(C)
    ]
    return gb, gd, (x, w1, w2), (xd, w1d, w2d), base_vals, dist_vals, res, resd


@pytest.mark.parametrize("variant", ["all_reduce", "scatter_gather"])
def test_mlp_facts_sound(variant):
    gb, gd, b_in, d_in, bv, dvs, res, resd = _mlp_pair(variant)
    p = Propagator(gb, gd, C)
    p.register_dup(b_in[0], d_in[0])
    p.register_shard(b_in[1], d_in[1], dim=1)
    p.register_shard(b_in[2], d_in[2], dim=0)
    p.run()
    n = check_facts(p, gb, gd, bv, dvs)
    assert n >= 6, f"too few facts checked ({n})"
    assert any(f.kind == DUP and f.base == res and f.clean
               for f in p.store.facts(resd)), "output not verified"


def test_all_to_all_layout_sound():
    """all_to_all resharding: the derived SHARD fact layout must hold."""
    rng = np.random.default_rng(1)
    S, D = 8, 12
    gb = Graph("base")
    x = gb.add("input", (), (S, D), "float64")
    t = gb.add("tanh", [x], (S, D), "float64")
    gb.mark_output(t)

    gd = Graph("dist")
    xd = gd.add("input", (), (S // C, D), "float64")  # sharded dim 0
    a2a = gd.add("all_to_all", [xd], (S, D // C), "float64",
                 {"split_axis": 1, "concat_axis": 0, "axes": ("model",), "tiled": True})
    td = gd.add("tanh", [a2a], (S, D // C), "float64")
    gd.mark_output(td)

    X = rng.standard_normal((S, D))
    dist_vals = [{xd: np.split(X, C, 0)[r]} for r in range(C)]
    p = Propagator(gb, gd, C)
    p.register_shard(x, xd, dim=0)
    p.run()
    n = check_facts(p, gb, gd, {x: X}, dist_vals)
    assert n >= 2
    # output should now be sharded along dim 1
    facts = [f for f in p.store.facts(td)]
    assert any(f.kind == SHARD for f in facts), facts


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@(given(st.integers(0, 3), st.integers(0, 1)) if HAVE_HYPOTHESIS
  else (lambda f: f))
@(settings(max_examples=8, deadline=None) if HAVE_HYPOTHESIS
  else (lambda f: f))
def test_gather_dims_sound(gdim_seed, tiled):
    """all_gather over any dim: derived DUP layout must hold numerically."""
    rng = np.random.default_rng(gdim_seed)
    S, D = 8, 4
    gb = Graph("base")
    x = gb.add("input", (), (S, D), "float64")
    t = gb.add("tanh", [x], (S, D), "float64")
    gb.mark_output(t)
    gdim = gdim_seed % 2
    gd = Graph("dist")
    xd = gd.add("input", (), (S // C, D), "float64")
    if tiled:
        shape = (S, D) if gdim == 0 else (S // C, D * C)
        ag = gd.add("all_gather", [xd], shape, "float64",
                    {"all_gather_dimension": gdim, "tiled": True, "axes": ("model",)})
    else:
        shape = (C, S // C, D) if gdim == 0 else (S // C, C, D)
        ag = gd.add("all_gather", [xd], shape, "float64",
                    {"all_gather_dimension": gdim, "tiled": False, "axes": ("model",)})
    gd.mark_output(ag)
    X = rng.standard_normal((S, D))
    dist_vals = [{xd: np.split(X, C, 0)[r]} for r in range(C)]
    p = Propagator(gb, gd, C)
    p.register_shard(x, xd, dim=0)
    p.run()
    check_facts(p, gb, gd, {x: X}, dist_vals)


def test_dp_gather_scatter_facts_sound():
    """The data-parallel batch rules: ``gather`` with batch-sharded indices
    (embedding lookup under DP) derives a sound SHARD fact, and
    ``scatter_add`` onto an all-zero operand (embedding gradient under DP)
    derives a sound PARTIAL(add) fact."""
    rng = np.random.default_rng(2)
    B, S, V, D = 8, 4, 10, 6
    dn_g = ("GatherDimensionNumbers(offset_dims=(2,), collapsed_slice_dims=(0,), "
            "start_index_map=(0,), operand_batching_dims=(), "
            "start_indices_batching_dims=())")
    dn_s = ("ScatterDimensionNumbers(update_window_dims=(2,), "
            "inserted_window_dims=(0,), scatter_dims_to_operand_dims=(0,))")

    def build(b):
        g = Graph()
        tbl = g.add("param", (), (V, D), "float64")
        ids = g.add("input", (), (b, S, 1), "int32")
        emb = g.add("gather", [tbl, ids], (b, S, D), "float64",
                    {"dimension_numbers": dn_g, "slice_sizes": (1, D)})
        upd = g.add("tanh", [emb], (b, S, D), "float64")
        zero = g.add("const", (), (V, D), "float64",
                     {"value_hash": "zv", "zero": True})
        scat = g.add("scatter_add", [zero, ids, upd], (V, D), "float64",
                     {"dimension_numbers": dn_s})
        g.mark_output(scat)
        return g, (tbl, ids, emb, zero, scat)

    gb, (tbl, ids, emb, zero, scat) = build(B)
    gd, (tbld, idsd, embd, zerod, scatd) = build(B // C)

    T = rng.standard_normal((V, D))
    idx = rng.integers(0, V, size=(B, S, 1))
    base_vals = {tbl: T, ids: idx, zero: np.zeros((V, D))}
    dist_vals = [
        {tbld: T, idsd: np.split(idx, C, 0)[r], zerod: np.zeros((V, D))}
        for r in range(C)
    ]
    p = Propagator(gb, gd, C)
    p.register_dup(tbl, tbld)
    p.register_shard(ids, idsd, dim=0)
    p.run()
    n = check_facts(p, gb, gd, base_vals, dist_vals)
    assert n >= 4, f"too few facts checked ({n})"
    assert any(f.kind == SHARD and f.base == emb
               for f in p.store.facts(embd)), "gather shard fact missing"
    assert any(f.kind == PARTIAL and f.reduce_op == "add" and f.base == scat
               for f in p.store.facts(scatd)), "scatter_add partial fact missing"


def test_sp_region_facts_sound():
    """The sequence-parallel region shape: a 3D partial sum enters the SP
    region through reduce_scatter along the *sequence* dim, an elementwise
    op runs sequence-sharded, and a seq-axis all_gather exits — every
    derived fact must hold under the simulator and the exit must be a clean
    duplicate of the baseline."""
    rng = np.random.default_rng(3)
    B, S, D = 2, 8, 6
    gb = Graph("base")
    x1 = gb.add("input", (), (B, S, D), "float64")
    t = gb.add("tanh", [x1], (B, S, D), "float64")
    gb.mark_output(t)

    gd = Graph("dist")
    xp = gd.add("input", (), (B, S, D), "float64")  # partial over ranks
    rs = gd.add("reduce_scatter", [xp], (B, S // C, D), "float64",
                {"scatter_dimension": 1, "reduce_op": "add",
                 "axes": ("model",), "tiled": True})
    td = gd.add("tanh", [rs], (B, S // C, D), "float64")
    ag = gd.add("all_gather", [td], (B, S, D), "float64",
                {"all_gather_dimension": 1, "tiled": True, "axes": ("model",)})
    gd.mark_output(ag)

    parts = [rng.standard_normal((B, S, D)) for _ in range(C)]
    X = np.sum(parts, axis=0)
    p = Propagator(gb, gd, C)
    # register the partial by hand: rank contributions sum to x1
    from repro.core.bijection import Layout
    from repro.core.relations import Fact

    p.emit(Fact(PARTIAL, x1, xp, C, Layout.identity((B, S, D)),
                reduce_op="add"))
    p.run()
    n = check_facts(p, gb, gd, {x1: X}, [{xp: parts[r]} for r in range(C)])
    assert n >= 2, f"too few facts checked ({n})"
    assert any(f.kind == SHARD and f.base == x1
               for f in p.store.facts(rs)), "reduce_scatter shard fact missing"
    # NOTE: tanh is not linear, so the shard (not partial) path must carry it
    assert any(f.kind == DUP and f.base == t and f.clean
               for f in p.store.facts(ag)), "seq all_gather did not discharge"


def test_rank_dynamic_slice_facts_sound():
    """The rank-indexed dynamic-slice rule: ``dynamic_slice(x, axis_index *
    chunk)`` over a replicated tensor is a clean shard — checked against the
    simulator (each rank slices its own chunk)."""
    rng = np.random.default_rng(4)
    T, E = 6, 8
    E_loc = E // C
    gb = Graph("base")
    w = gb.add("input", (), (T, E), "float64")
    t = gb.add("tanh", [w], (T, E), "float64")
    gb.mark_output(t)

    gd = Graph("dist")
    wd = gd.add("input", (), (T, E), "float64")  # replicated
    ai = gd.add("axis_index", [], (), "int64", {"axes": ("model",)})
    ck = gd.add("const", [], (), "int64", {"value": E_loc, "value_hash": "ck"})
    z0 = gd.add("const", [], (), "int64",
                {"value": 0, "value_hash": "z0", "zero": True})
    st = gd.add("mul", [ai, ck], (), "int64")
    ds = gd.add("dynamic_slice", [wd, z0, st], (T, E_loc), "float64",
                {"slice_sizes": (T, E_loc)})
    td = gd.add("tanh", [ds], (T, E_loc), "float64")
    gd.mark_output(td)

    W = rng.standard_normal((T, E))
    p = Propagator(gb, gd, C)
    p.register_dup(w, wd)
    p.run()
    n = check_facts(p, gb, gd, {w: W}, [{wd: W} for _ in range(C)])
    assert n >= 2, f"too few facts checked ({n})"
    assert any(f.kind == SHARD and f.base == w
               for f in p.store.facts(ds)), "rank slice shard fact missing"
    assert any(f.kind == SHARD and f.base == t
               for f in p.store.facts(td)), "shard did not carry downstream"


def test_orthogonal_collective_carries_facts():
    """A collective over a *different* mesh axis is congruence-transparent
    for the verified axis: with a same-params all_reduce in both graphs,
    shard facts carry through to the matching baseline collective.  (The
    numpy simulator models a single axis, so this is the symbolic half; the
    numeric half is covered by the composite-scenario equivalence test.)"""
    B, H = 8, 6
    params = {"reduce_op": "add", "axes": ("other",), "groups": "full"}

    gb = Graph("base")
    xb = gb.add("input", (), (B, H), "float64")
    arb = gb.add("all_reduce", [xb], (B, H), "float64", dict(params))
    tb = gb.add("tanh", [arb], (B, H), "float64")
    gb.mark_output(tb)

    gd = Graph("dist")
    xd = gd.add("input", (), (B // C, H), "float64")  # sharded over "model"
    ard = gd.add("all_reduce", [xd], (B // C, H), "float64", dict(params))
    td = gd.add("tanh", [ard], (B // C, H), "float64")
    gd.mark_output(td)

    p = Propagator(gb, gd, C)  # verifying axis "model"
    p.register_shard(xb, xd, dim=0)
    p.run()
    facts = p.store.facts(ard)
    assert any(f.kind == SHARD and f.base == arb for f in facts), [
        f.short() for f in facts]
    assert any(f.kind == SHARD and f.base == tb
               for f in p.store.facts(td))
