"""The registry-driven scenario subsystem: composable Plan axes, the three
new scenarios (sp-forward, ep-moe-forward, tpdp-forward) verifying clean and
catching injected bugs with localized BugSites, base-trace sharing across
scenarios of one plan, registry lookup errors, and the pairs.py shim."""
import pytest

from repro.core.inject import drop_all_reduce, wrong_all_gather_dim, wrong_scatter_dim
from repro.verify import (
    DEFAULT_SCENARIOS,
    Plan,
    PlanError,
    Scenario,
    Session,
    verify,
)
from repro.verify.cli import main as cli_main

ARCH = "qwen3_4b"
MOE_ARCH = "mixtral_8x7b"
TP = 4


# --------------------------------------------------------------- registry
def test_registry_covers_all_plan_kinds():
    kinds = set(DEFAULT_SCENARIOS.kinds())
    assert {"tp-forward", "tp-decode", "dp-forward", "dp-grad", "stage",
            "sp-forward", "ep-moe-forward", "tpdp-forward"} <= kinds


def test_registry_unknown_kind_is_plan_error():
    with pytest.raises(PlanError, match="unknown scenario"):
        DEFAULT_SCENARIOS.get("zz-forward")


def test_registry_double_registration_rejected():
    with pytest.raises(ValueError, match="twice"):
        DEFAULT_SCENARIOS.scenario("tp-forward", "model")(lambda *a: None)


def test_registry_describe_lists_docs():
    text = DEFAULT_SCENARIOS.describe()
    assert "sp-forward" in text and "ep-moe-forward" in text


# ------------------------------------------------------- plan composition
@pytest.mark.parametrize("kw", [
    dict(tp=1, sp=True),               # sp needs a tp axis
    dict(tp=4, sp=True, mode="decode"),
    dict(ep=0),
    dict(ep=4, mode="grad", dp=2),     # ep composes with forward only
    dict(tp=4, composite=True),        # composite needs dp too
    dict(dp=2, composite=True),        # ... and tp
    dict(tp=4, dp=2, composite=True, sp=True),  # sp breaks the chain arg
    dict(tp=1, dp=1, ep=1),            # nothing to verify
])
def test_plan_axis_validation_errors(kw):
    with pytest.raises(PlanError):
        Plan(**kw)


def test_plan_axis_expansion():
    assert [s.name for s in Plan(tp=8, sp=True).scenarios()] == ["sp-forward"]
    assert [s.name for s in Plan(ep=4).scenarios()] == ["ep-moe-forward"]
    assert [s.name for s in Plan(tp=8, ep=8).scenarios()] == [
        "tp-forward", "ep-moe-forward"]
    assert [s.name for s in Plan(tp=4, dp=2, composite=True).scenarios()] == [
        "tp-forward", "tpdp-forward"]
    assert [s.name for s in Plan(tp=4, dp=2).scenarios()] == [
        "tp-forward", "dp-forward"]
    assert Plan(tp=8, sp=True).describe() == "tp8+sp-forward"
    assert Plan(ep=4).describe() == "ep4-forward"
    assert Plan(tp=4, dp=2, composite=True).describe() == "tp4+dp2x-forward"


def test_plan_round_trips_through_dict():
    p = Plan(tp=4, dp=2, composite=True, seq=16)
    assert Plan(**{k: v for k, v in p.to_dict().items()
                   if v is not None or k in ("layers", "batch")}) == p


# ------------------------------------------------------------- sp-forward
def test_sp_forward_verifies_and_catches_bugs():
    with Session() as s:
        plan = Plan(tp=TP, sp=True, layers=2)
        good = s.verify(ARCH, plan)
        assert good.verified, good.summary()
        assert good.scenarios[0]["scenario"] == "sp-forward"
        # wrong all_gather dim on an sp_exit gather: silent layout bug
        bad = s.verify(ARCH, plan, mutate_dist=lambda gd:
                       wrong_all_gather_dim(gd, index=0).graph)
        assert not bad.verified and bad.bug_sites
        # wrong reduce_scatter dim on an sp_enter scatter
        bad2 = s.verify(ARCH, plan, mutate_dist=lambda gd:
                        wrong_scatter_dim(gd, index=1).graph)
        assert not bad2.verified and bad2.bug_sites
        assert bad2.bug_sites[0].src  # localized to a source site


def test_sp_forward_seq_divisibility_checked():
    with pytest.raises(PlanError, match="seq"):
        verify(ARCH, Plan(tp=TP, sp=True, layers=2, seq=30))


# --------------------------------------------------------- ep-moe-forward
def test_ep_moe_forward_verifies_and_catches_bugs():
    with Session() as s:
        plan = Plan(ep=4, layers=2)
        good = s.verify(MOE_ARCH, plan)
        assert good.verified, good.summary()
        assert good.scenarios[0]["scenario"] == "ep-moe-forward"
        # dropping the expert-axis all_reduce leaves the accumulation partial
        bad = s.verify(MOE_ARCH, plan, mutate_dist=lambda gd:
                       drop_all_reduce(gd, index=0).graph)
        assert not bad.verified and bad.bug_sites


def test_ep_moe_forward_exercises_loopred_slicegrp():
    """The EP scenario must discharge through the LOOPRED/SLICEGRP relation
    family (paper Fig. 8), not merely congruence."""
    from repro.core.relations import LOOPRED, SLICEGRP
    from repro.core.rules import Propagator
    from repro.verify.plan import TP_AXIS
    from repro.verify.scenarios import build_pair

    plan = Plan(ep=4, layers=2)
    pair = build_pair(MOE_ARCH, plan, Scenario("ep-moe-forward", TP_AXIS, 4))
    p = Propagator(pair.base, pair.dist, 4)
    for f in pair.input_facts:
        b, d = pair.base_inputs[f.base_index], pair.dist_inputs[f.dist_index]
        if f.kind == "dup":
            p.register_dup(b, d)
        else:
            p.register_shard(b, d, f.dim)
    p.run()
    kinds = {f.kind for facts in p.store.by_dist.values() for f in facts}
    assert SLICEGRP in kinds and LOOPRED in kinds


def test_ep_rejects_dense_arch_and_bad_degree():
    with pytest.raises(PlanError, match="no experts"):
        verify(ARCH, Plan(ep=4, layers=2))
    with pytest.raises(PlanError, match="not divisible"):
        verify(MOE_ARCH, Plan(ep=3, layers=2))


# ----------------------------------------------------------- tpdp-forward
def test_composite_verifies_and_catches_bugs():
    with Session() as s:
        plan = Plan(tp=TP, dp=2, composite=True, layers=2)
        good = s.verify(ARCH, plan)
        assert good.verified, good.summary()
        assert [r["scenario"] for r in good.scenarios] == [
            "tp-forward", "tpdp-forward"]
        # dropping a model-axis psum desyncs the 2D program from the TP
        # baseline: the composite row must flag it
        bad = s.verify(ARCH, plan, mutate_dist=lambda gd:
                       drop_all_reduce(gd, index=1).graph)
        assert not bad.verified and bad.bug_sites
        rows = {r["scenario"]: r["verified"] for r in bad.scenarios}
        assert not rows["tpdp-forward"]


def test_composite_rejects_moe():
    with pytest.raises(PlanError, match="MoE"):
        verify(MOE_ARCH, Plan(tp=2, dp=2, composite=True, layers=2, batch=2))


# ------------------------------------------------------ base-trace sharing
def test_base_trace_shared_across_scenarios():
    """tp-forward and sp-forward trace the same baseline program over the
    same avals: the second scenario must reuse the session's base trace
    (cache keyed on (arch, aval signature), not scenario name)."""
    with Session() as s:
        cold = s.verify(ARCH, Plan(tp=TP, layers=2))
        shared = s.verify(ARCH, Plan(tp=TP, sp=True, layers=2))
    assert not cold.cache.base_trace_cached
    assert not cold.cache.trace_cached
    assert shared.cache.base_trace_cached, "sp-forward re-traced the baseline"
    assert not shared.cache.trace_cached  # the *pair* is new, only base hits
    assert shared.scenarios[0]["base_trace_cached"]
    assert s.stats()["cached_base_traces"] >= 1


def test_base_trace_share_preserves_verdict_and_facts():
    with Session() as s:
        s.verify(ARCH, Plan(tp=TP, layers=2))  # warm the shared base trace
        shared_sp = s.verify(ARCH, Plan(tp=TP, sp=True, layers=2))
    solo_sp = verify(ARCH, Plan(tp=TP, sp=True, layers=2))
    assert shared_sp.verified and solo_sp.verified
    assert shared_sp.num_facts == solo_sp.num_facts
    assert shared_sp.num_base_nodes == solo_sp.num_base_nodes


# ------------------------------------------------------------------- CLI
def test_cli_list_exits_zero(capsys):
    assert cli_main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "sp-forward" in out and "ep-moe-forward" in out
    assert "mixtral_8x7b" in out


def test_cli_missing_arch_exits_two(capsys):
    assert cli_main([]) == 2


def test_cli_new_axis_flags():
    assert cli_main([ARCH, "--tp", str(TP), "--sp", "--layers", "2",
                     "--quiet"]) == 0
    assert cli_main([MOE_ARCH, "--ep", "4", "--layers", "2", "--quiet"]) == 0
    # unknown-scenario-shaped errors exit 2 with the available set
    assert cli_main([ARCH, "--ep", "4", "--layers", "2", "--quiet"]) == 2
    assert cli_main([ARCH, "--tp", str(TP), "--sp", "--decode",
                     "--quiet"]) == 2
    assert cli_main([ARCH, "--tp", str(TP), "--dp", "2", "--composite",
                     "--layers", "2", "--quiet"]) == 0


# ------------------------------------------------------------ pairs shim
def test_pairs_shim_warns_and_matches_registry():
    from repro.configs import get_config
    from repro.verify import pairs
    from repro.verify.scenarios import round_layers

    cfg = round_layers(get_config(ARCH), 2)
    pairs._warned.clear()  # once-per-process guard (see docs/API.md)
    with pytest.warns(DeprecationWarning):
        pair = pairs.tp_forward_pair(ARCH, cfg, TP, 1, 32)
    assert pair.size == TP and pair.axis == "model"
    # stable re-exports stay warning-free
    assert pairs.build_pair is not None and pairs.GraphPair is not None


def test_legacy_scenarios_verdict_parity():
    """The five pre-existing scenario kinds keep their verdicts through the
    registry refactor."""
    with Session() as s:
        assert s.verify(ARCH, Plan(tp=TP, layers=2)).verified
        assert s.verify(ARCH, Plan.decode(tp=TP, layers=2)).verified
        assert s.verify(ARCH, Plan(dp=2, layers=2)).verified
        assert s.verify(ARCH, Plan.grad(dp=2, layers=2, seq=8)).verified
        assert s.verify(ARCH, Plan.pipeline(stages=2, tp=TP, layers=4)).verified
