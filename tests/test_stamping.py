"""Layer stamping (repro.core.stamp): the stamped graph must be node-by-node
identical to a full trace, verdicts/facts must match with stamping (and
worklist sharding) on vs off, and the memo fast path must actually serve
stamped layers from the template cache (MemoStats counters)."""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core.ir import Graph
from repro.core.modelverify import verify_model_tp
from repro.verify.pairs import (
    _stamped_parts,
    _tp_decode_parts as _decode_pair,
    _tp_forward_parts as _forward_pair,
    round_layers as _round_layers,
)
from repro.verify.specs import spec_input_facts as _spec_input_facts


def _stamped_pair(cfg, pair_fn, periods_per_block):
    parts, _ = _stamped_parts(cfg, pair_fn, periods_per_block)
    return parts
from repro.core.partition import partition_layers
from repro.core.rules import Propagator, WorklistEngine
from repro.core.stamp import TRACE_PERIODS, stamp_graph
from repro.core.trace import LAYER_TAG_STRIDE
from repro.core.verifier import VerifyOptions

TP = 2


def _smoke_cfg(arch: str, n_layers: int):
    return dataclasses.replace(get_config(arch, smoke=True), n_layers=n_layers)


def _assert_graphs_equal(stamped: Graph, full: Graph) -> None:
    assert len(stamped.nodes) == len(full.nodes)
    for a, b in zip(stamped.nodes, full.nodes):
        assert a == b, f"node {a.id}:\n  stamped: {a}\n  full:    {b}"
    assert stamped.outputs == full.outputs


@pytest.mark.parametrize("arch", ["llama3_8b", "jamba_1_5_large"])
def test_stamped_forward_equals_full_trace(arch):
    cfg = get_config(arch, smoke=True)
    per = cfg.block_period
    total = 6 if per > 1 else 8
    pair_fn = lambda c: _forward_pair(arch, c, TP, 1, 16)
    stamped = _stamped_pair(_smoke_cfg(arch, total * per), pair_fn, per)
    assert stamped is not None, "periodic trace must stamp, not fall back"
    sb, b_in, sd, d_in, _ = stamped
    assert sb.stamp is not None and sd.stamp is not None
    fb, fb_in, fd, fd_in, _ = pair_fn(_smoke_cfg(arch, total * per))
    _assert_graphs_equal(sb, fb)
    _assert_graphs_equal(sd, fd)
    assert b_in == fb_in and d_in == fd_in


def test_stamped_decode_equals_full_trace():
    arch, total = "llama3_8b", 8
    pair_fn = lambda c: _decode_pair(arch, c, TP, 2, 64)
    stamped = _stamped_pair(_smoke_cfg(arch, total), pair_fn, 1)
    assert stamped is not None
    sb, _, sd, _, _ = stamped
    fb, _, fd, _, _ = pair_fn(_smoke_cfg(arch, total))
    _assert_graphs_equal(sb, fb)
    _assert_graphs_equal(sd, fd)


def test_stamp_verdict_parity():
    reports = {
        stamp: verify_model_tp("llama3_8b", tp=TP, smoke=True, n_layers=8,
                               seq=16, options=VerifyOptions(stamp=stamp))
        for stamp in (False, True)
    }
    on, off = reports[True], reports[False]
    assert on.verified and off.verified
    assert on.outputs_ok == off.outputs_ok
    assert on.num_facts == off.num_facts
    assert on.unverified_count == off.unverified_count


def test_memo_fast_path_stats():
    rep = verify_model_tp("llama3_8b", tp=TP, smoke=True, n_layers=8, seq=16)
    m = rep.memo
    assert rep.verified
    # layers 1..7 are structural clones of layer 0's steady state
    assert m.memo_hits >= 6, m
    # every stamped period (beyond the 3 traced) serves its fingerprint and
    # ext-input lists from the template cache
    assert m.fp_cached >= 8 - TRACE_PERIODS - 1, m
    # memo hits settle their nodes: no cleanup re-dispatch
    assert m.settled_nodes > 0, m


def _fact_keys(gb, b_in, gd, d_in, flat_specs, workers: int):
    """Drive per-layer worklist rewriting (as PartitionedVerifier does,
    without memoization) and return the derived fact-key set."""
    prop = Propagator(gb, gd, TP)
    eng = WorklistEngine(prop, workers=workers)
    for f in _spec_input_facts(flat_specs):
        b, d = b_in[f.base_index], d_in[f.dist_index]
        if f.kind == "dup":
            prop.register_dup(b, d)
        else:
            prop.register_shard(b, d, f.dim)
    try:
        for plan in partition_layers(gb, gd):
            if plan.dist_nodes:
                eng.run(plan.dist_nodes)
        eng.run()
    finally:
        eng.close()
    return {f.key() for facts in prop.store.by_dist.values() for f in facts}


def test_fact_set_parity_stamp_and_shard():
    """Identical fact sets with stamping on vs off and with the sharded
    parallel sweep on vs off (the acceptance property of this pipeline)."""
    arch, total = "llama3_8b", 6
    pair_fn = lambda c: _forward_pair(arch, c, TP, 1, 16)
    stamped = _stamped_pair(_smoke_cfg(arch, total), pair_fn, 1)
    assert stamped is not None
    full = pair_fn(_smoke_cfg(arch, total))
    ref = _fact_keys(*full, workers=0)
    assert _fact_keys(*stamped, workers=0) == ref
    assert _fact_keys(*stamped, workers=4) == ref
    assert ref


def test_round_layers_whole_periods():
    cfg = get_config("jamba_1_5_large", smoke=True)
    assert _round_layers(cfg, 5).n_layers == 8  # rounded up to block_period=4


def test_concat_extension_uses_family_extent():
    """A postamble concat mixing a per-period family with an unrelated input
    must grow by the family member's extent, not the last input's."""
    S = LAYER_TAG_STRIDE
    g = Graph()
    x = g.add("input", (), (4,), "float32")
    w = g.add("input", (), (4, 4), "float32")  # unrelated concat operand
    outs = []
    h = x
    for li in range(3):
        h = g.add("tanh", [h], (4,), "float32", layer=li * S)
        outs.append(h)
    rs = [g.add("reshape", [o], (1, 4), "float32", {"new_sizes": (1, 4)})
          for o in outs]
    cat = g.add("concat", rs + [w], (7, 4), "float32", {"dimension": 0})
    g.mark_output(cat)
    sg = stamp_graph(g, 5, lambda t: t // S)
    assert sg is not None
    out = sg[sg.outputs[0]]
    assert len(out.inputs) == 6  # 5 family members + w
    assert out.shape == (9, 4)  # grew by extra_periods * member extent (1)
    assert out.inputs[-1] == w  # unrelated operand untouched


def test_stamp_falls_back_on_irregular_trace():
    """A trace whose periods differ structurally must refuse to stamp."""
    S = LAYER_TAG_STRIDE
    g = Graph()
    x = g.add("input", (), (4,), "float32")
    for li in range(3):
        x = g.add("tanh", [x], (4,), "float32", layer=li * S)
        if li == 2:  # period 2 has an extra node: lengths diverge
            x = g.add("neg", [x], (4,), "float32", layer=li * S)
    g.mark_output(x)
    assert stamp_graph(g, 6, lambda t: t // S) is None

    # fewer traced periods than TRACE_PERIODS must also refuse
    g2 = Graph()
    x = g2.add("input", (), (4,), "float32")
    for li in range(2):
        x = g2.add("tanh", [x], (4,), "float32", layer=li * S)
    g2.mark_output(x)
    assert stamp_graph(g2, 6, lambda t: t // S) is None
