"""Persistent warm-start store + delta re-verification contracts.

The store's one promise is *cold-fallback soundness*: a hit replays the
exact traced pair + templates, and ANY mismatch — schema bump, rules-hash
drift, truncated file, flipped byte — degrades to a cold verify, never a
wrong verdict.  Delta re-verification's promise is *parity*: re-verifying
a mutated graph through a clean session's diffed template cache must
produce the same verdict, bug sites and canonical fact set as a
from-scratch run, for every registered injector.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core.inject import DEFAULT_INJECTORS
from repro.core.ir import GraphDelta, diff_graphs
from repro.core.verifier import VerifyOptions
from repro.verify import Plan, Session
from repro.verify.scenarios import build_pair
from repro.verify.store import DiskCache, rules_schema_hash

ARCH = "qwen3_4b"
PLAN = Plan(tp=4, layers=2, seq=32)


def _canon(f):
    lay = f.layout
    lk = None if lay is None else (lay.atoms, lay.perm, lay.dst_groups)
    return (f.kind, f.base, f.dist, f.size, lk, f.reduce_op, f.dim,
            f.nchunk, f.index, f.idxset)


def _verify_captured(session, **kw):
    """session.verify + the canonical fact set of every Propagator built."""
    import repro.core.verifier as V

    captured = []
    orig = V.Propagator

    class _Capture(orig):
        def __init__(self, *a, **kws):
            super().__init__(*a, **kws)
            captured.append(self)

    V.Propagator = _Capture
    try:
        rep = session.verify(ARCH, PLAN, **kw)
    finally:
        V.Propagator = orig
    facts = {_canon(f) for p in captured
             for fl in p.store.by_dist.values() for f in fl}
    return rep, facts


# ---------------------------------------------------------------- round trip


def test_disk_roundtrip_fresh_session(tmp_path):
    cache = str(tmp_path / "vcache")
    with Session(cache_dir=cache) as s:
        cold = s.verify(ARCH, PLAN)
    assert cold.verified and not cold.cache.disk_warm
    assert s.stats()["disk"]["saves"] == 1
    # fresh session, nothing carried over but the directory
    with Session(cache_dir=cache) as s2:
        warm = s2.verify(ARCH, PLAN)
    assert warm.verified and warm.cache.disk_warm
    assert s2.stats()["disk"] == {"hits": 1, "misses": 0, "saves": 0}
    assert cold.canonical() == warm.canonical()


def test_disk_roundtrip_fresh_process(tmp_path):
    """The real contract: a different PYTHONHASHSEED, a different process."""
    cache = str(tmp_path / "vcache")
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    prog = (
        "import json, sys\n"
        "from repro.verify import Plan, Session\n"
        f"s = Session(cache_dir={cache!r})\n"
        f"rep = s.verify({ARCH!r}, Plan(tp=4, layers=2, seq=32))\n"
        "print(json.dumps({'verified': rep.verified,"
        " 'disk_warm': rep.cache.disk_warm,"
        " 'canonical': rep.canonical()}))\n"
    )

    def run(seed):
        env = dict(os.environ, PYTHONPATH=src, PYTHONHASHSEED=seed,
                   JAX_PLATFORMS="cpu")
        out = subprocess.run([sys.executable, "-c", prog], env=env,
                             capture_output=True, text=True, check=True)
        return json.loads(out.stdout.splitlines()[-1])

    a, b = run("1"), run("2")
    assert a["verified"] and not a["disk_warm"]
    assert b["verified"] and b["disk_warm"]
    assert a["canonical"] == b["canonical"]


# ------------------------------------------------------------- cold fallback


def _populated(tmp_path):
    cache = str(tmp_path / "vcache")
    with Session(cache_dir=cache) as s:
        assert s.verify(ARCH, PLAN).verified
    return cache


def test_rules_hash_mismatch_falls_back_cold(tmp_path, monkeypatch):
    cache = _populated(tmp_path)
    # a rule-registry change shifts the content address: old entries are
    # simply never found
    import repro.verify.store as store_mod
    monkeypatch.setattr(store_mod, "_rules_hash", "deadbeef" * 8)
    assert rules_schema_hash() == "deadbeef" * 8
    with Session(cache_dir=cache) as s:
        rep = s.verify(ARCH, PLAN)
    assert rep.verified and not rep.cache.disk_warm
    assert s.stats()["disk"]["misses"] >= 1


def test_schema_bump_falls_back_cold(tmp_path, monkeypatch):
    cache = _populated(tmp_path)
    import repro.verify.store as store_mod
    monkeypatch.setattr(store_mod, "STORE_SCHEMA_VERSION", 999)
    monkeypatch.setattr(store_mod, "_rules_hash", None)  # recompute
    with Session(cache_dir=cache) as s:
        rep = s.verify(ARCH, PLAN)
    assert rep.verified and not rep.cache.disk_warm


@pytest.mark.parametrize("damage", ["truncate", "flip", "garbage", "empty"])
def test_corrupted_entry_tolerated(tmp_path, damage):
    cache = _populated(tmp_path)
    (entry,) = [os.path.join(cache, f) for f in os.listdir(cache)]
    raw = open(entry, "rb").read()
    if damage == "truncate":
        raw = raw[: len(raw) // 2]
    elif damage == "flip":
        raw = raw[:50] + bytes([raw[50] ^ 0xFF]) + raw[51:]
    elif damage == "garbage":
        raw = b"not a cache entry"
    else:
        raw = b""
    open(entry, "wb").write(raw)
    with Session(cache_dir=cache) as s:
        rep = s.verify(ARCH, PLAN)
    assert rep.verified and not rep.cache.disk_warm
    assert s.stats()["disk"]["misses"] == 1


def test_unwritable_payload_returns_false(tmp_path):
    store = DiskCache(str(tmp_path / "vcache"))
    assert store.save(("k",), object(), lambda: None) is False  # unpicklable
    assert store.load(("k",)) is None
    assert store.saves == 0


# ------------------------------------------------------------- diff_graphs


def _tp_pair():
    return build_pair(ARCH, PLAN, PLAN.scenarios()[0], stamp=False)


def test_diff_identity_and_inplace_edit():
    pair = _tp_pair()
    g = pair.dist
    d = diff_graphs(g, g)
    assert d == GraphDelta((), len(g.nodes), len(g.nodes), 0)
    assert d.map_old(0) == 0 and d.map_old(len(g.nodes) - 1) == len(g.nodes) - 1


@pytest.mark.parametrize("name", DEFAULT_INJECTORS.names())
def test_diff_covers_every_injector_surgery(name):
    pair = _tp_pair()
    spec = DEFAULT_INJECTORS.get(name)
    inj = spec(pair.dist)
    if inj is None:
        pytest.skip(f"{name}: no applicable site in tp-forward")
    mut = inj.graph
    delta = diff_graphs(pair.dist, mut)
    assert delta is not None, f"{name}: bounded surgery must diff"
    assert delta.changed, f"{name}: surgery must mark changed nodes"
    shift = len(mut.nodes) - len(pair.dist.nodes)
    assert delta.shift == shift
    # alignment soundness: every new node outside `changed` is
    # field-identical to its mapped old node
    changed = set(delta.changed)
    imaged = {}
    for old_id in range(len(pair.dist.nodes)):
        nid = delta.map_old(old_id)
        if nid is not None:
            imaged[nid] = old_id
    for new_id, node in enumerate(mut.nodes):
        if new_id in changed:
            continue
        old = pair.dist.nodes[imaged[new_id]]
        assert (old.op, old.shape, old.dtype, old.params) == (
            node.op, node.shape, node.dtype, node.params), (name, new_id)


def test_diff_rejects_oversized_edit():
    from repro.core.ir import Graph

    g = _tp_pair().dist
    t = Graph(g.name)
    t.nodes = g.nodes[:10]
    t.outputs = [9]
    assert diff_graphs(g, t, max_changed=4) is None


# ------------------------------------------------------- delta re-verify


@pytest.mark.parametrize("name", DEFAULT_INJECTORS.names())
def test_delta_reverify_parity_per_injector(name):
    spec = DEFAULT_INJECTORS.get(name)

    def mut(g):
        inj = spec(g)
        return g if inj is None else inj.graph

    # delta path: clean verify warms the session, the mutated run diffs
    with Session(options=VerifyOptions()) as s:
        clean = s.verify(ARCH, PLAN)
        assert clean.verified
        rep_d, facts_d = _verify_captured(s, mutate_dist=mut,
                                          mutate_pure=True)
    # from-scratch: a fresh session goes straight to the mutated run
    with Session(options=VerifyOptions(delta=False)) as s2:
        rep_f, facts_f = _verify_captured(s2, mutate_dist=mut,
                                          mutate_pure=True)
    assert rep_d.verified == rep_f.verified
    sites_d = {(b.src, b.category) for b in rep_d.bug_sites}
    sites_f = {(b.src, b.category) for b in rep_f.bug_sites}
    assert sites_d == sites_f, name
    assert facts_d == facts_f, f"{name}: delta fact set diverged"
    if not rep_f.verified:  # injector had an applicable site
        assert rep_d.cache.delta_nodes > 0, f"{name}: delta path must engage"


def test_delta_disabled_still_sound():
    spec = DEFAULT_INJECTORS.get("drop_all_reduce")

    def mut(g):
        inj = spec(g)
        return g if inj is None else inj.graph

    with Session(options=VerifyOptions(delta=False)) as s:
        assert s.verify(ARCH, PLAN).verified
        rep = s.verify(ARCH, PLAN, mutate_dist=mut, mutate_pure=True)
    assert not rep.verified and rep.cache.delta_nodes == 0
