"""Distributed-vs-single-device numerical equivalence.

Runs in a SUBPROCESS with ``--xla_force_host_platform_device_count=8`` (the
device count must be set before jax initializes; the main pytest process
stays single-device).  Checks, per architecture family:

  * shard_map TP forward == single-device forward
  * TP+DP train step == single-device train step (loss + params)
  * ZeRO-1 step == replicated AdamW step
  * sequence parallelism == plain TP
  * int8-compressed gradient all-reduce within quantization error
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

_SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return out.stdout


_PRELUDE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.models import Model
    from repro.parallel.ctx import ParallelCtx
    from repro.parallel.sharding import param_specs, batch_spec
    from repro.launch.mesh import make_debug_mesh
    from repro.data import make_batch_for
    from repro.configs.base import ShapeSpec

    assert len(jax.devices()) == 8, jax.devices()

    def tp_forward(arch, tp=2, dp=4, sp=False, steps=0, zero1=False, compress="none"):
        import dataclasses
        # structural equivalence is checked in f32 (bf16 reassociation noise
        # and MoE top-k tie flips are covered by tests/test_arch_smoke.py)
        cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
        mesh = make_debug_mesh(tp=tp, dp=dp)
        ctx = ParallelCtx.from_mesh(mesh, dp=("data",), sp=sp)
        model_d = Model(cfg, ctx)
        model_s = Model(cfg)
        key = jax.random.PRNGKey(0)
        params = model_s.init(key)
        shp = ShapeSpec("t", 16, 4 * dp, "train")
        batch = make_batch_for(cfg, shp, seed=1)
        pspecs = param_specs(jax.eval_shape(lambda: params))
        bspecs = batch_spec(batch, ("data",))
        return cfg, mesh, ctx, model_d, model_s, params, batch, pspecs, bspecs
""")


FWD_TEMPLATE = _PRELUDE + textwrap.dedent("""
    arch = "{arch}"
    cfg, mesh, ctx, md, ms, params, batch, pspecs, bspecs = tp_forward(arch, sp={sp})
    ref = np.asarray(ms.loss(params, batch), np.float32)
    from repro.compat import shard_map
    fn = shard_map(lambda p, b: jax.lax.pmean(md.loss(p, b), "data"), mesh=mesh,
                   in_specs=(pspecs, bspecs), out_specs=P(), check_vma=False)
    with mesh:
        dist = np.asarray(jax.jit(fn)(params, batch), np.float32)
    err = abs(float(dist) - float(ref)) / max(abs(float(ref)), 1e-6)
    print("arch", arch, "ref", ref, "dist", dist, "relerr", err)
    assert err < 0.005, (ref, dist)
    print("OK")
""")


@pytest.mark.parametrize("arch", [
    "qwen3_4b", "gemma_2b", "granite_moe_3b", "mamba2_130m", "jamba_1_5_large",
    "hubert_xlarge",
])
def test_tp_loss_matches_single_device(arch):
    _run(FWD_TEMPLATE.format(arch=arch, sp=False))


@pytest.mark.parametrize("arch", ["qwen3_4b", "mamba2_130m"])
def test_sequence_parallel_matches(arch):
    _run(FWD_TEMPLATE.format(arch=arch, sp=True))


TRAIN_TEMPLATE = _PRELUDE + textwrap.dedent("""
    from repro.train.trainer import TrainConfig, make_step_fn
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

    arch = "{arch}"
    cfg, mesh, ctx, md, ms, params, batch, pspecs, bspecs = tp_forward(
        arch, zero1={zero1}, compress="{compress}")
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1), microbatches={micro},
                       remat=False, zero1={zero1}, grad_compress="{compress}")

    # single-device reference step
    loss_ref, grads = jax.value_and_grad(lambda p: ms.loss(p, batch))(params)
    opt_ref = adamw_init(params)
    newp_ref, _, _ = adamw_update(tcfg.opt, params, grads, opt_ref)

    # distributed step
    if {zero1}:
        from repro.launch.dryrun import _zero_flags_from_specs, _opt_specs, _zero_opt_shapes
        flags = _zero_flags_from_specs(jax.eval_shape(lambda: params), 4, pspecs)
        step = make_step_fn(md, tcfg, shard_flags=flags)
        opt = {{"m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
               "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
               "step": jnp.zeros((), jnp.int32)}}
        ospecs = _opt_specs(pspecs, zero1=True, dp_last="data", flags=flags)
    else:
        step = make_step_fn(md, tcfg)
        opt = adamw_init(params)
        ospecs = {{"m": pspecs, "v": pspecs, "step": P()}}
    mspecs = {{"loss": P(), "grad_norm": P(), "lr": P()}}
    from repro.compat import shard_map
    fn = shard_map(step, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
                       out_specs=(pspecs, ospecs, mspecs), check_vma=False)
    with mesh:
        newp, newopt, metrics = jax.jit(fn)(params, opt, batch)
    loss_d = float(metrics["loss"])
    err = abs(loss_d - float(loss_ref)) / max(abs(float(loss_ref)), 1e-6)
    print("loss ref/dist:", float(loss_ref), loss_d, "err", err)
    assert err < 0.02
    # parameters after one step must agree
    worst = 0.0
    for (ka, a), (kb, b) in zip(
        jax.tree_util.tree_flatten_with_path(newp_ref)[0][:50],
        jax.tree_util.tree_flatten_with_path(newp)[0][:50],
    ):
        diff = np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)))
        worst = max(worst, float(diff))
    print("worst param delta:", worst)
    assert worst < {tol}, worst
    print("OK")
""")


def test_train_step_matches_single_device():
    _run(TRAIN_TEMPLATE.format(arch="qwen3_4b", zero1=False, compress="none",
                               micro=1, tol=2e-2))


def test_train_step_microbatched():
    _run(TRAIN_TEMPLATE.format(arch="qwen3_4b", zero1=False, compress="none",
                               micro=4, tol=2e-2))


def test_zero1_matches_adamw():
    _run(TRAIN_TEMPLATE.format(arch="qwen3_4b", zero1=True, compress="none",
                               micro=1, tol=2e-2))


def test_int8_compressed_allreduce_close():
    _run(TRAIN_TEMPLATE.format(arch="qwen3_4b", zero1=False, compress="int8",
                               micro=1, tol=5e-2))


CP_TEMPLATE = _PRELUDE + textwrap.dedent("""
    # context-parallel flash decode == single-device decode (jamba family)
    import dataclasses
    cfg = dataclasses.replace(get_config("jamba_1_5_large", smoke=True), dtype="float32")
    mesh = make_debug_mesh(tp=2, dp=4)   # data axis = 4 -> cp shards
    ctx = ParallelCtx.from_mesh(mesh, dp=None, sp=False, cp="data")
    md, ms = Model(cfg, ctx), Model(cfg)
    key = jax.random.PRNGKey(0)
    params = ms.init(key)
    B, MAXLEN = 2, 32
    tok = jnp.arange(B, dtype=jnp.int32) + 3
    pos = jnp.int32(5)
    cache_s = ms.init_cache(B, MAXLEN)
    logits_ref, _ = ms.decode_step(params, tok, cache_s, pos)

    from repro.parallel.sharding import cache_specs
    pspecs = param_specs(jax.eval_shape(lambda: params))
    cshapes = jax.eval_shape(lambda: ms.init_cache(B, MAXLEN))
    cspecs = cache_specs(cshapes, None, cp="data")
    cache_d = ms.init_cache(B, MAXLEN)  # zeros; same content
    from repro.compat import shard_map
    fn = shard_map(lambda p, t, c, q: md.decode_step(p, t, c, q)[0],
                       mesh=mesh, in_specs=(pspecs, P(), cspecs, P()),
                       out_specs=P(None, "model"), check_vma=False)
    with mesh:
        logits_d = jax.jit(fn)(params, tok, cache_d, pos)
    a = np.asarray(logits_ref, np.float32); b = np.asarray(logits_d, np.float32)
    scale = max(a.std(), 1.0)
    bad = np.mean(np.abs(a - b) / scale > 0.1)
    print("cp decode mismatch frac:", bad)
    assert bad < 0.02
    print("OK")
""")


def test_context_parallel_flash_decode():
    _run(CP_TEMPLATE)
