"""End-to-end training integration: the launcher's verification gate, loss
decrease on the synthetic stream, checkpoint/kill/resume fault tolerance,
and elastic resume onto a different mesh layout."""
import os
import re
import subprocess
import sys
from pathlib import Path

_SRC = str(Path(__file__).resolve().parents[1] / "src")


def _train(args: list[str], devices: int = 8, timeout: int = 800):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def _losses(stdout: str) -> list[float]:
    return [float(m) for m in re.findall(r"loss (\d+\.\d+)", stdout)]


def test_train_verify_gate_and_loss_decreases(tmp_path):
    out = _train(["--arch", "qwen3_4b", "--smoke", "--steps", "40",
                  "--tp", "2", "--dp", "4", "--seq", "64", "--batch", "8",
                  "--lr", "3e-3"])
    assert "VERIFIED" in out
    losses = _losses(out)
    assert losses[0] - losses[-1] > 0.3, f"no learning: {losses}"


def test_kill_and_resume_continues(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    # phase 1: 20 steps, checkpoint every 10
    out1 = _train(["--arch", "mamba2_130m", "--smoke", "--steps", "20",
                   "--tp", "1", "--dp", "2", "--seq", "32", "--batch", "4",
                   "--ckpt-dir", ckpt, "--ckpt-every", "10", "--skip-verify"],
                  devices=2)
    assert "saved step 20" in out1
    # phase 2: "restart after failure" — resumes from step 20
    out2 = _train(["--arch", "mamba2_130m", "--smoke", "--steps", "30",
                   "--tp", "1", "--dp", "2", "--seq", "32", "--batch", "4",
                   "--ckpt-dir", ckpt, "--ckpt-every", "10", "--resume",
                   "--skip-verify"], devices=2)
    assert "resumed" in out2 and "step 20" in out2
    losses1, losses2 = _losses(out1), _losses(out2)
    # resumed loss continues from (not above) where phase 1 ended
    assert losses2[0] <= losses1[0], (losses1, losses2)


def test_elastic_resume_different_mesh(tmp_path):
    """A checkpoint written under dp=2 restores under tp=2 x dp=2 (elastic
    re-sharding happens at restore; the data stream replays its position)."""
    ckpt = str(tmp_path / "ckpt")
    _train(["--arch", "qwen3_4b", "--smoke", "--steps", "10",
            "--tp", "1", "--dp", "2", "--seq", "32", "--batch", "8",
            "--ckpt-dir", ckpt, "--ckpt-every", "10", "--skip-verify"],
           devices=2)
    out = _train(["--arch", "qwen3_4b", "--smoke", "--steps", "14",
                  "--tp", "2", "--dp", "2", "--seq", "32", "--batch", "8",
                  "--ckpt-dir", ckpt, "--ckpt-every", "10", "--resume",
                  "--skip-verify"], devices=4)
    assert "resumed" in out
    assert _losses(out), out
