"""End-to-end verifier tests: real jax traces, partitioning/memoization,
the injected-bug suite (paper Tables 4/5 analogue), and framework layers."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh

from repro.core import (
    inject_all,
    trace,
    trace_sharded,
    verify_graphs,
    verify_sharded,
)
from repro.core.relations import DUP, SHARD
from repro.core.verifier import InputFact, VerifyOptions

C = 8
B, H, F, L = 4, 32, 64, 6


def base_fn(x, w1s, w2s):
    for i in range(L):
        with jax.named_scope(f"layer{i}"):
            h = jnp.tanh(x @ w1s[i])
            x = h @ w2s[i] + x
    return x


def dist_fn(x, w1s, w2s):
    for i in range(L):
        with jax.named_scope(f"layer{i}"):
            h = jnp.tanh(x @ w1s[i])
            x = jax.lax.psum(h @ w2s[i], "model") + x
    return x


AVALS = (
    jax.ShapeDtypeStruct((B, H), jnp.float32),
    jax.ShapeDtypeStruct((L, H, F), jnp.float32),
    jax.ShapeDtypeStruct((L, F, H), jnp.float32),
)
SPECS = (P(), P(None, None, "model"), P(None, "model", None))


def test_verify_megatron_stack():
    rep = verify_sharded(base_fn, dist_fn, *AVALS, size=C, in_specs=SPECS, out_specs=P())
    assert rep.verified
    assert rep.memo is not None and rep.memo.memo_hits == L - 1
    assert rep.num_facts > 50


def test_verify_without_partitioning_agrees():
    rep = verify_sharded(
        base_fn, dist_fn, *AVALS, size=C, in_specs=SPECS, out_specs=P(),
        options=VerifyOptions(partition=False))
    assert rep.verified


@pytest.fixture(scope="module")
def traced_pair():
    mesh = abstract_mesh((C,), ("model",))
    gb, b_in, _ = trace(base_fn, *AVALS, name="base")
    gd, d_in, _ = trace_sharded(dist_fn, mesh, SPECS, P(), *AVALS)
    facts = [InputFact(DUP, 0, 0), InputFact(SHARD, 1, 1, 2), InputFact(SHARD, 2, 2, 1)]
    return gb, gd, b_in, d_in, facts


def test_injection_suite_detected_and_localized(traced_pair):
    """Every injected silent error is detected; the bug site is localized to
    the exact source line (paper §5.3 / Tables 4-5)."""
    gb, gd, b_in, d_in, facts = traced_pair
    clean = verify_graphs(gb, gd, size=C, input_facts=facts,
                          base_inputs=b_in, dist_inputs=d_in)
    assert clean.verified

    injections = inject_all(gd)
    assert len(injections) >= 6
    detected = localized = categorized = 0
    for inj in injections:
        rep = verify_graphs(gb, inj.graph, size=C, input_facts=facts,
                            base_inputs=b_in, dist_inputs=d_in)
        assert not rep.verified, f"{inj.name} NOT detected"
        detected += 1
        if any(b.src == inj.site for b in rep.bug_sites):
            localized += 1
        if any(b.category == inj.category for b in rep.bug_sites):
            categorized += 1
    assert detected == len(injections)
    assert localized == len(injections), "all bugs must localize to their site"
    assert categorized >= len(injections) - 2  # category labels are best-effort


def test_layout_bug_repair_suggestion(traced_pair):
    """The BSH-style reshape bug must come with a synthesized repair
    bijection (Algorithm 2 output, as in paper Fig. 9/10)."""
    from repro.core.inject import swap_reshape_dims

    gb, gd, b_in, d_in, facts = traced_pair
    inj = swap_reshape_dims(gd)
    assert inj is not None
    rep = verify_graphs(gb, inj.graph, size=C, input_facts=facts,
                        base_inputs=b_in, dist_inputs=d_in)
    assert not rep.verified
    repairs = [b.repair for b in rep.bug_sites if b.repair]
    assert repairs, "expected a synthesized repair sequence"
    ops = [op for op, _ in repairs[0]]
    assert "transpose" in ops


def test_verify_sequence_parallel_region():
    """SP (reduce_scatter + all_gather) verifies equivalent to plain psum."""

    def base(x, w1, w2):
        h = jnp.tanh(x @ w1)
        return h @ w2

    def dist_sp(x, w1, w2):
        h = jnp.tanh(x @ w1)
        y = h @ w2
        y = jax.lax.psum_scatter(y, "model", scatter_dimension=0, tiled=True)
        return jax.lax.all_gather(y, "model", axis=0, tiled=True)

    avals = (
        jax.ShapeDtypeStruct((16, H), jnp.float32),
        jax.ShapeDtypeStruct((H, F), jnp.float32),
        jax.ShapeDtypeStruct((F, H), jnp.float32),
    )
    rep = verify_sharded(
        base, dist_sp, *avals, size=C,
        in_specs=(P(), P(None, "model"), P("model", None)), out_specs=P())
    assert rep.verified, rep.summary()


def test_verify_vocab_parallel_loss_pattern():
    """Vocab-parallel logsumexp: pmax(max) + psum(sum exp) == full-logit."""

    def base(lg):
        m = lg.max(axis=-1)
        return jnp.log(jnp.exp(lg - m[..., None]).sum(-1)) + m

    def dist(lg):
        m = jax.lax.pmax(lg.max(axis=-1), "model")
        return jnp.log(jax.lax.psum(jnp.exp(lg - m[..., None]).sum(-1), "model")) + m

    avals = (jax.ShapeDtypeStruct((B, 64), jnp.float32),)
    rep = verify_sharded(base, dist, *avals, size=C,
                         in_specs=(P(None, "model"),), out_specs=P())
    assert rep.verified, rep.summary()
