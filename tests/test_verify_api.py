"""The unified repro.verify API: Plan validation, Session warm-start
(template-cache reuse across calls), Report JSON round-trip, CLI exit
codes, and the localize frontier-selection regression."""
import json

import pytest

from repro.core.ir import Graph
from repro.core.relations import RelStore
from repro.core.report import BugSite, Report, severity_of
from repro.core.verifier import localize
from repro.verify import Plan, PlanError, Session, verify
from repro.verify.cli import main as cli_main

ARCH = "qwen3_4b"
TP = 4


# ------------------------------------------------------------------- Plan
@pytest.mark.parametrize("kw", [
    dict(tp=1, dp=1),                 # nothing to verify
    dict(tp=0),                       # non-positive degree
    dict(tp=-2),
    dict(tp=True),                    # bool is not a degree
    dict(tp=2, mode="sideways"),      # unknown mode
    dict(tp=1, mode="decode"),        # decode needs tp > 1
    dict(tp=4, dp=2, mode="decode"),  # decode is tp-axis only
    dict(dp=1, mode="grad"),          # grad needs dp > 1
    dict(tp=4, dp=2, mode="grad"),    # grad is dp-axis only
    dict(tp=2, stages=4),             # stages require mode="pipeline"
    dict(tp=2, stages=1, mode="pipeline"),
    dict(tp=1, stages=4, mode="pipeline"),   # per-stage tp needed
    dict(tp=2, dp=2, batch=3),        # batch not divisible by dp
    dict(tp=2, batch=0),
])
def test_plan_validation_errors(kw):
    with pytest.raises(PlanError):
        Plan(**kw)


def test_plan_constructors_and_scenarios():
    assert [s.name for s in Plan(tp=16).scenarios()] == ["tp-forward"]
    assert [s.name for s in Plan(tp=8, dp=2).scenarios()] == [
        "tp-forward", "dp-forward"]
    assert [s.name for s in Plan.decode(tp=16).scenarios()] == ["tp-decode"]
    assert [s.name for s in Plan.grad(dp=8).scenarios()] == ["dp-grad"]
    assert [s.name for s in Plan.pipeline(stages=3).scenarios()] == [
        "stage0", "stage1", "stage2"]
    p = Plan(tp=8, dp=2)
    assert p.describe() == "tp8+dp2-forward"
    assert Plan(**{k: v for k, v in p.to_dict().items()
                   if v is not None or k in ("layers", "batch")}) == p


def test_plan_is_declarative_value():
    assert Plan(tp=4) == Plan(tp=4)
    assert hash(Plan(tp=4)) == hash(Plan(tp=4))
    assert Plan(tp=4) != Plan(tp=8)


# ---------------------------------------------------------------- Session
def test_session_warm_vs_cold():
    """Second verify of the same arch/plan must be served from the session
    caches: no re-tracing, fingerprints from the template cache, memo hits
    on every layer — with the same verdict and outputs."""
    with Session() as s:
        plan = Plan(tp=TP, layers=2)
        cold = s.verify(ARCH, plan)
        warm = s.verify(ARCH, plan)
    assert cold.verified and warm.verified
    assert not cold.cache.trace_cached
    assert warm.cache.trace_cached, "second call re-traced"
    assert warm.cache.fp_cached > 0, "fingerprints not served from cache"
    assert warm.cache.memo_hits >= cold.cache.memo_hits
    assert warm.timings.trace_s == 0.0 and warm.timings.stamp_s == 0.0
    assert warm.outputs_ok == cold.outputs_ok
    # the whole point: warm re-verify is measurably cheaper than cold
    assert warm.elapsed_s < cold.elapsed_s


def test_session_verdict_matches_legacy_entry_point():
    """Acceptance: Session cold verdicts and fact counts are identical to
    the deprecated one-shots for TP-forward and TP-decode."""
    from repro.core import modelverify
    from repro.core.modelverify import verify_decode_tp, verify_model_tp

    with Session() as s:
        fwd = s.verify(ARCH, Plan(tp=TP, layers=2))
        dec = s.verify(ARCH, Plan.decode(tp=TP, layers=2))
    modelverify._warned.clear()  # once-per-process guard (see docs/API.md)
    with pytest.warns(DeprecationWarning):
        old_fwd = verify_model_tp(ARCH, tp=TP, n_layers=2)
    with pytest.warns(DeprecationWarning):
        old_dec = verify_decode_tp(ARCH, tp=TP, n_layers=2)
    assert (fwd.verified, fwd.num_facts) == (old_fwd.verified, old_fwd.num_facts)
    assert (dec.verified, dec.num_facts) == (old_dec.verified, old_dec.num_facts)


def test_session_mutated_runs_bypass_caches():
    from repro.core.inject import drop_all_reduce

    with Session() as s:
        plan = Plan(tp=TP, layers=2)
        good = s.verify(ARCH, plan)
        bad = s.verify(ARCH, plan,
                       mutate_dist=lambda gd: drop_all_reduce(gd, index=1).graph)
        good2 = s.verify(ARCH, plan)
    assert good.verified and not bad.verified
    assert bad.bug_sites, "injected bug produced no sites"
    assert good2.verified and good2.cache.trace_cached, (
        "mutated run must not poison the session caches")


def test_hybrid_plan_scenarios_reported():
    with Session() as s:
        rep = s.verify(ARCH, Plan(tp=TP, dp=2, layers=2))
    assert rep.verified
    assert [x["scenario"] for x in rep.scenarios] == ["tp-forward", "dp-forward"]
    assert all(x["verified"] for x in rep.scenarios)


def test_grad_plan_verifies():
    rep = verify(ARCH, Plan.grad(dp=2, layers=2, seq=8))
    assert rep.verified
    assert rep.scenarios[0]["scenario"] == "dp-grad"


def test_pipeline_plan_verifies():
    rep = verify(ARCH, Plan.pipeline(stages=2, tp=TP, layers=4))
    assert rep.verified
    assert [x["scenario"] for x in rep.scenarios] == ["stage0", "stage1"]


# ----------------------------------------------------------------- Report
def test_report_json_round_trip():
    from repro.core.inject import drop_all_reduce

    with Session() as s:
        rep = s.verify(ARCH, Plan(tp=TP, layers=2),
                       mutate_dist=lambda gd: drop_all_reduce(gd, index=1).graph)
    assert not rep.verified and rep.bug_sites
    j = rep.to_json(indent=2)
    back = Report.from_json(j)
    assert back.to_json(indent=2) == j  # stable round trip
    assert back.verified == rep.verified
    assert [b.category for b in back.bug_sites] == [
        b.category for b in rep.bug_sites]
    assert back.plan == rep.plan and back.arch == ARCH
    # bug sites are severity-ranked
    ranks = [b.rank for b in rep.bug_sites]
    assert ranks == sorted(ranks)


def test_report_json_schema_guard():
    rep = verify(ARCH, Plan(tp=TP, layers=2))
    d = json.loads(rep.to_json())
    d["schema"] = 999
    with pytest.raises(ValueError):
        Report.from_json(json.dumps(d))


def test_severity_mapping():
    assert severity_of("missing_all_reduce") == "high"
    assert severity_of("precision_mismatch") == "medium"
    assert severity_of("unverified_frontier") == "low"
    assert severity_of("anything_else") == "medium"
    assert BugSite("f.py:1", "add", 0, "missing_all_reduce", "d").severity == "high"


# -------------------------------------------------------------------- CLI
def test_cli_exit_0_verified(tmp_path):
    out = tmp_path / "report.json"
    rc = cli_main([ARCH, "--tp", str(TP), "--layers", "2", "--quiet",
                   "--json", str(out)])
    assert rc == 0
    d = json.loads(out.read_text())
    assert d["verified"] is True and d["schema"] == 1


def test_cli_exit_1_unverified():
    rc = cli_main([ARCH, "--tp", str(TP), "--layers", "2", "--quiet",
                   "--inject", "drop_all_reduce"])
    assert rc == 1


def test_cli_exit_2_usage():
    assert cli_main(["no_such_arch", "--tp", "4"]) == 2
    assert cli_main([ARCH, "--tp", "0"]) == 2  # PlanError
    assert cli_main([ARCH]) == 2  # no parallelism declared
    assert cli_main([ARCH, "--tp", "4", "--inject", "bogus"]) == 2
    with pytest.raises(SystemExit) as e:
        cli_main([ARCH, "--tp", "not_an_int"])  # argparse usage error
    assert e.value.code == 2


# ------------------------------------------------- localize frontier (fix)
def _mini_graph():
    """dist graph: inputs a,b -> c=const -> m=mul(a,c) -> r=add(m,b)."""
    g = Graph("dist")
    a = g.add("input", (), (4,))
    b = g.add("input", (), (4,))
    c = g.add("const", (), (4,))
    m = g.add("mul", (a, c), (4,), src="f.py:1")
    r = g.add("add", (m, b), (4,), src="f.py:2")
    g.outputs = [r]
    return g, (a, b, c, m, r)


def test_localize_frontier_selection():
    """Regression for the tangled frontier conditionals: a node is on the
    frontier iff ALL of its inputs are verified or attribute-only leaves
    (const/iota/axis_index).  Downstream nodes whose unverified input is a
    real (non-leaf) node must NOT be reported."""
    from repro.core.bijection import Layout
    from repro.core.relations import DUP, Fact

    base, _ = _mini_graph()  # structure irrelevant for the frontier walk
    dist, (a, b, c, m, r) = _mini_graph()
    store = RelStore()
    # inputs a and b verified; const c carries no facts; m unverified
    store.add(Fact(DUP, a, a, 2, Layout.identity((4,))))
    store.add(Fact(DUP, b, b, 2, Layout.identity((4,))))

    sites = localize(base, dist, store)
    # m's inputs are {verified a, const c} -> frontier; r's inputs include
    # the unverified non-leaf m -> NOT the frontier
    assert [s.node for s in sites] == [m]
    assert sites[0].category == "unverified_frontier"

    # once m verifies, r (inputs m,b both verified) becomes the frontier
    store.add(Fact(DUP, m, m, 2, Layout.identity((4,))))
    sites = localize(base, dist, store)
    assert [s.node for s in sites] == [r]


def test_localize_input_leaf_not_frontier():
    """An unverified *graph input* (op='input', no facts) disqualifies its
    consumers from the frontier — pinning the legacy behavior."""
    from repro.core.bijection import Layout
    from repro.core.relations import DUP, Fact

    base, _ = _mini_graph()
    dist, (a, b, c, m, r) = _mini_graph()
    store = RelStore()
    store.add(Fact(DUP, a, a, 2, Layout.identity((4,))))
    store.add(Fact(DUP, m, m, 2, Layout.identity((4,))))
    # b (a real input leaf) has no facts: r = add(m, b) must not be reported
    assert localize(base, dist, store) == []
